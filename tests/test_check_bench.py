"""The CI benchmark-regression gate (``benchmarks/check_bench.py``):
ratio metrics gated with floors + baseline-relative slack, wall-clock
informational; a deliberately broken ratio must fail."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.check_bench import (CHECKS, RatioCheck, check_artifact,
                                    lookup, run_gate)   # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _healthy_estimate(speedup=30.0):
    return {"speedup_warm": speedup, "speedup_cold": 40.0}


def test_healthy_ratios_pass_against_baseline():
    checks = CHECKS["BENCH_estimate.json"]
    assert check_artifact("BENCH_estimate.json", _healthy_estimate(),
                          _healthy_estimate(), checks) == []


def test_deliberately_broken_ratio_fails():
    """The gate's reason to exist: batched silently collapsing to serial
    speed (ratio ~1) must fail even though every wall-clock is 'fine'."""
    checks = CHECKS["BENCH_estimate.json"]
    broken = {"speedup_warm": 1.1, "speedup_cold": 40.0}
    failures = check_artifact("BENCH_estimate.json", broken,
                              _healthy_estimate(), checks)
    assert len(failures) == 1
    assert "speedup_warm" in failures[0] and "regressed" in failures[0]


def test_slow_runner_passes_via_baseline_relative_bar():
    """Below the absolute floor but within slack of the committed
    baseline: a uniformly slow runner must not flake the gate."""
    chk = (RatioCheck(("speedup_warm",), floor=10.0, rel_slack=0.5),)
    fresh = {"speedup_warm": 6.0}
    assert check_artifact("x", fresh, {"speedup_warm": 11.0}, chk) == []
    # ... but collapsing far below the baseline still fails
    assert check_artifact("x", {"speedup_warm": 2.0},
                          {"speedup_warm": 11.0}, chk)


def test_missing_metric_and_missing_artifact_fail(tmp_path):
    checks = CHECKS["BENCH_estimate.json"]
    failures = check_artifact("BENCH_estimate.json", {}, None, checks)
    assert all("missing" in f for f in failures) and len(failures) == 2
    # a bench step that emitted nothing is a failure, not a skip
    failures = run_gate(str(tmp_path), str(tmp_path),
                        {"BENCH_estimate.json": checks})
    assert failures and "fresh artifact missing" in failures[0]


def test_applies_if_exempts_interpret_mode_kernels():
    checks = CHECKS["BENCH_kernels.json"]
    fresh = {"speed_bar_applies": False,
             "grids": [{"pallas_speedup_vs_vectorized_warm": 0.3}]}
    assert check_artifact("BENCH_kernels.json", fresh, None, checks) == []
    fresh["speed_bar_applies"] = True
    assert check_artifact("BENCH_kernels.json", fresh, None, checks)


def test_lookup_walks_lists_with_negative_indices():
    blob = {"grids": [{"r": 1.0}, {"r": 2.5}]}
    assert lookup(blob, ("grids", "-1", "r")) == 2.5


def test_gate_passes_on_committed_artifacts_identity():
    """The committed snapshots must pass against themselves — the exact
    comparison CI makes on an unchanged tree (modulo runner speed)."""
    present = [n for n in CHECKS if os.path.exists(os.path.join(ARTIFACTS,
                                                                n))]
    if not present:
        pytest.skip("no committed BENCH artifacts")
    failures = run_gate(ARTIFACTS, ARTIFACTS,
                        {n: CHECKS[n] for n in present})
    assert failures == [], failures


def test_baseline_schema_malformed_json_fails(tmp_path):
    """Satellite: a corrupt committed baseline must fail the gate loudly
    instead of silently downgrading its checks to the absolute floor."""
    from benchmarks.check_bench import validate_baselines
    (tmp_path / "BENCH_estimate.json").write_text("{not json")
    failures = validate_baselines(str(tmp_path))
    assert len(failures) == 1 and "unreadable" in failures[0]


def test_baseline_schema_missing_metric_fails(tmp_path):
    from benchmarks.check_bench import validate_baselines
    (tmp_path / "BENCH_estimate.json").write_text(
        json.dumps({"speedup_warm": 30.0}))  # speedup_cold missing
    failures = validate_baselines(str(tmp_path))
    assert len(failures) == 1 and "speedup_cold" in failures[0]
    (tmp_path / "BENCH_estimate.json").write_text(
        json.dumps({"speedup_warm": 30.0, "speedup_cold": "fast"}))
    failures = validate_baselines(str(tmp_path))
    assert len(failures) == 1 and "non-numeric" in failures[0]


def test_baseline_schema_orphan_artifact_fails(tmp_path):
    """A committed BENCH_*.json nobody gates is a silent coverage hole."""
    from benchmarks.check_bench import validate_baselines
    (tmp_path / "BENCH_mystery.json").write_text("{}")
    failures = validate_baselines(str(tmp_path))
    assert len(failures) == 1 and "no CHECKS entry" in failures[0]


def test_baseline_schema_non_object_root_fails(tmp_path):
    from benchmarks.check_bench import validate_baselines
    (tmp_path / "BENCH_estimate.json").write_text("[1, 2]")
    failures = validate_baselines(str(tmp_path))
    assert len(failures) == 1 and "root is list" in failures[0]


def test_committed_baselines_satisfy_schema():
    from benchmarks.check_bench import validate_baselines
    assert validate_baselines(ARTIFACTS) == []
